"""AOT lowering: HLO text validity, manifest schema, config mirroring."""

import json
import re

import pytest

from compile import model as M
from compile.aot import lower_apply, lower_eval, lower_grad, lower_train, manifest
from compile.configs import ARTIFACT_SETS, DEFAULT_SETS, MODELS

ASET = ARTIFACT_SETS["micro_b4"]


@pytest.fixture(scope="module")
def train_hlo():
    return lower_train(ASET, 8)


def test_train_hlo_structure(train_hlo):
    assert "ENTRY" in train_hlo
    assert "HloModule" in train_hlo
    # 6 inputs: params, m, v, decay_mask, knobs f32[3], tokens
    for i in range(6):
        assert f"parameter({i})" in train_hlo
    assert "parameter(6)" not in train_hlo
    n = M.n_params(ASET.cfg())
    assert f"f32[{n}]" in train_hlo
    assert "f32[3]" in train_hlo  # the packed step/lr/clip knob vector
    assert f"s32[{ASET.batch_size},9]" in train_hlo  # tokens at seqlen 8
    # output layout 3: the root carries the three state tensors plus the
    # packed f32[10] stats tensor as separate results
    assert f"(f32[{n}]{{0}}, f32[{n}]{{0}}, f32[{n}]{{0}}, f32[10]{{0}})" in train_hlo


def test_eval_hlo_structure():
    text = lower_eval(ASET, ASET.cfg().max_seqlen)
    assert "ENTRY" in text
    assert "parameter(1)" in text


def test_grad_hlo_structure():
    text = lower_grad(ASET, 8)
    n = M.n_params(ASET.cfg())
    # 2 inputs (params, shard tokens), 2 results (grads, loss)
    assert f"f32[{n}]{{0}} parameter(0)" in text
    assert f"s32[{ASET.batch_size},9]{{1,0}} parameter(1)" in text
    assert f"(f32[{n}]{{0}}, f32[])" in text


def test_apply_hlo_structure():
    text = lower_apply(ASET)
    n = M.n_params(ASET.cfg())
    # 6 inputs: params, m, v, decay_mask, knobs f32[4], reduced grads
    for i in range(6):
        assert f"parameter({i})" in text
    assert "parameter(6)" not in text
    assert "f32[4]" in text  # [step, lr, clip_norm, mean_loss]
    # same untupled state+stats root as the fused step
    assert f"(f32[{n}]{{0}}, f32[{n}]{{0}}, f32[{n}]{{0}}, f32[10]{{0}})" in text
    # batch/seqlen independence: no 2-D token array anywhere (s32 scalars
    # from internal loop counters are fine)
    assert not re.search(r"s32\[\d+,\d+\]", text)


def test_manifest_schema():
    man = manifest(ASET)
    js = json.loads(json.dumps(man))  # round-trips
    assert js["set"] == "micro_b4"
    assert js["n_params"] == M.n_params(ASET.cfg())
    assert js["seqlen_buckets"] == list(ASET.seqlen_buckets)
    assert len(js["params"]) == len(M.param_specs(ASET.cfg()))
    assert js["output_layout"] == 4
    assert js["train_inputs"] == ["params", "m", "v", "decay_mask", "knobs", "tokens"]
    assert js["knob_fields"] == ["step", "lr", "clip_norm"]
    assert js["train_outputs"] == ["params", "m", "v", "stats"]
    # layout 4: split grad/apply entry points for the replica engine
    assert js["grad_artifacts"] == {str(s): f"grad_s{s}.hlo.txt" for s in ASET.seqlen_buckets}
    assert js["apply_artifact"] == "apply.hlo.txt"
    assert js["grad_inputs"] == ["params", "tokens"]
    assert js["grad_outputs"] == ["grads", "loss"]
    assert js["apply_inputs"] == ["params", "m", "v", "decay_mask", "knobs", "grads"]
    assert js["apply_knob_fields"] == ["step", "lr", "clip_norm", "mean_loss"]
    assert js["apply_outputs"] == ["params", "m", "v", "stats"]
    assert js["stats_fields"][0] == "loss"
    assert js["stats_fields"][3] == "var_max"
    assert js["stats_fields"][6:] == [f"urms_{g}" for g in M.URMS_GROUPS]
    assert len(js["stats_fields"]) == 10
    total = sum(p["size"] for p in js["params"])
    assert total == js["n_params"]
    # offsets are the running sum (Rust init relies on this)
    off = 0
    for p in js["params"]:
        assert p["offset"] == off
        off += p["size"]


def test_bucket_ladders():
    for name in DEFAULT_SETS:
        aset = ARTIFACT_SETS[name]
        full = MODELS[aset.model].max_seqlen
        assert aset.seqlen_buckets[-1] == full
        for b in aset.seqlen_buckets:
            assert b % 8 == 0, "paper's Tensor-Core multiple-of-8 constraint"
        assert list(aset.seqlen_buckets) == sorted(set(aset.seqlen_buckets))
        if aset.full_only:
            assert aset.seqlen_buckets == (full,)


def test_batch_scaling_mirrors_paper():
    """base → large batch is 8x, the paper's 512 → 4K ratio."""
    assert ARTIFACT_SETS["tiny_b64"].batch_size == 8 * ARTIFACT_SETS["tiny_b8"].batch_size
    assert ARTIFACT_SETS["small_b64"].batch_size == 8 * ARTIFACT_SETS["small_b8"].batch_size


def test_gpt3_warmup_ladder():
    """bsz-warmup rungs double up to the target batch (paper: 16 → 256)."""
    rungs = [ARTIFACT_SETS[f"gpt3_b{b}"].batch_size for b in (2, 4, 8, 16)]
    assert rungs == [2, 4, 8, 16]
    assert all(ARTIFACT_SETS[f"gpt3_b{b}"].full_only for b in (2, 4, 8, 16))
    assert not ARTIFACT_SETS["gpt3_b64"].full_only
