"""L1 flash-attention kernel vs pure-jnp oracle: shape/dtype/block sweeps."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from compile.kernels.attention import (
    attention_vmem_bytes,
    default_block,
    flash_attention,
)
from compile.kernels.ref import attention_ref


def rand_qkv(seed, b, h, s, dh, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    return [jax.random.normal(k, (b, h, s, dh), dtype) for k in keys]


def tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([8, 16, 32, 64, 128]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_fwd_matches_ref(b, h, s, dh, seed):
    q, k, v = rand_qkv(seed, b, h, s, dh)
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@given(
    s=st.sampled_from([32, 64]),
    bq=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**8),
)
def test_fwd_block_independence(s, bq, bk, seed):
    """The online-softmax result must not depend on the tile schedule."""
    q, k, v = rand_qkv(seed, 1, 2, s, 16)
    full = flash_attention(q, k, v, block_q=s, block_k=s)
    tiled = flash_attention(q, k, v, block_q=bq, block_k=bk)
    assert jnp.max(jnp.abs(full - tiled)) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwd_dtypes(dtype):
    q, k, v = rand_qkv(7, 2, 2, 32, 16, dtype)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = attention_ref(q, k, v)
    assert out.dtype == dtype
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))) < tol(dtype)


def test_causality():
    """Output at position i must be independent of tokens after i."""
    q, k, v = rand_qkv(3, 1, 1, 32, 8)
    out = flash_attention(q, k, v, block_q=8, block_k=8)
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    out2 = flash_attention(q, k2, v2, block_q=8, block_k=8)
    assert jnp.max(jnp.abs(out[:, :, :20] - out2[:, :, :20])) < 1e-6
    assert jnp.max(jnp.abs(out[:, :, 20:] - out2[:, :, 20:])) > 1e-3


def test_non_causal():
    q, k, v = rand_qkv(11, 1, 2, 32, 16)
    out = flash_attention(q, k, v, causal=False, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=False)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


def test_prefix_consistency():
    """Causal attention over a prefix equals the prefix of the full result —
    the invariant the SLW truncation batcher relies on."""
    q, k, v = rand_qkv(5, 1, 2, 64, 16)
    full = flash_attention(q, k, v)
    half = flash_attention(q[:, :, :32], k[:, :, :32], v[:, :, :32])
    assert jnp.max(jnp.abs(full[:, :, :32] - half)) < 2e-5


# ---------------------------------------------------------------------------
# Backward (custom VJP)
# ---------------------------------------------------------------------------

@given(
    s=st.sampled_from([16, 32, 64]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**8),
)
def test_bwd_matches_ref(s, dh, seed):
    q, k, v = rand_qkv(seed, 2, 2, s, dh)

    def loss_k(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, block_q=min(16, s), block_k=min(32, s))))

    def loss_r(q, k, v):
        return jnp.sum(jnp.sin(attention_ref(q, k, v)))

    gk = jax.grad(loss_k, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, (0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert jnp.max(jnp.abs(a - b)) < 5e-5


def test_bwd_jit():
    q, k, v = rand_qkv(9, 1, 2, 32, 16)
    g = jax.jit(jax.grad(lambda q, k, v: jnp.sum(flash_attention(q, k, v) ** 2), (0, 1, 2)))
    gr = jax.grad(lambda q, k, v: jnp.sum(attention_ref(q, k, v) ** 2), (0, 1, 2))(q, k, v)
    for a, b in zip(g(q, k, v), gr):
        assert jnp.max(jnp.abs(a - b)) < 5e-5


# ---------------------------------------------------------------------------
# Static structure
# ---------------------------------------------------------------------------

def test_default_block():
    assert default_block(8) == 8
    assert default_block(64) == 64
    assert default_block(128) == 128
    assert default_block(192) == 64
    assert default_block(256) == 128
    with pytest.raises(ValueError):
        default_block(12)


def test_rejects_bad_blocks():
    q, k, v = rand_qkv(0, 1, 1, 32, 8)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=24)


def test_vmem_estimate_monotone():
    """VMEM per grid step grows with block size, not with seqlen once tiled —
    the property the §Perf roofline table is built on."""
    small = attention_vmem_bytes(64, 32)
    tiled_256 = attention_vmem_bytes(256, 32)   # block 128
    tiled_512 = attention_vmem_bytes(512, 32)   # block 128 too
    assert small < tiled_256
    assert tiled_256 == tiled_512
