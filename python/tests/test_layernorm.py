"""L1 fused LayerNorm kernel vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, strategies as st

from compile.kernels.layernorm import _pick_block_rows, layer_norm
from compile.kernels.ref import layernorm_ref


def rand(seed, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype)


@given(
    rows=st.sampled_from([1, 2, 8, 33, 64, 256]),
    d=st.sampled_from([8, 32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_fwd_matches_ref(rows, d, seed):
    x = rand(seed, (rows, d))
    g = rand(seed + 1, (d,)) * 0.1 + 1.0
    b = rand(seed + 2, (d,)) * 0.1
    out = layer_norm(x, g, b)
    ref = layernorm_ref(x, g, b)
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


@pytest.mark.parametrize("shape", [(4, 8, 32), (2, 3, 5, 16), (7, 24)])
def test_nd_shapes(shape):
    x = rand(0, shape)
    g = jnp.ones(shape[-1])
    b = jnp.zeros(shape[-1])
    out = layer_norm(x, g, b)
    ref = layernorm_ref(x, g, b)
    assert out.shape == shape
    assert jnp.max(jnp.abs(out - ref)) < 1e-5


def test_bf16_io_f32_stats():
    x = rand(1, (16, 64), jnp.bfloat16)
    g = jnp.ones(64)
    b = jnp.zeros(64)
    out = layer_norm(x, g, b)
    assert out.dtype == jnp.bfloat16
    ref = layernorm_ref(x, g, b)
    assert jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))) < 5e-2


@given(
    rows=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**8),
)
def test_bwd_matches_ref(rows, d, seed):
    x = rand(seed, (rows, d))
    g = rand(seed + 1, (d,)) * 0.1 + 1.0
    b = rand(seed + 2, (d,)) * 0.1

    def lk(x, g, b):
        return jnp.sum(jnp.cos(layer_norm(x, g, b)))

    def lr(x, g, b):
        return jnp.sum(jnp.cos(layernorm_ref(x, g, b)))

    gk = jax.grad(lk, (0, 1, 2))(x, g, b)
    gr = jax.grad(lr, (0, 1, 2))(x, g, b)
    for a, bb in zip(gk, gr):
        # dgamma/dbeta are cross-row partial sums; slightly looser.
        assert jnp.max(jnp.abs(a - bb)) < 1e-3


def test_block_rows_independence():
    x = rand(4, (64, 32))
    g = jnp.ones(32)
    b = jnp.zeros(32)
    a = layer_norm(x, g, b, block_rows=8)
    c = layer_norm(x, g, b, block_rows=64)
    assert jnp.max(jnp.abs(a - c)) < 1e-6


def test_normalization_invariants():
    """gamma=1, beta=0 output has ~zero mean / unit variance per row."""
    x = rand(5, (32, 128)) * 7.0 + 3.0
    y = layer_norm(x, jnp.ones(128), jnp.zeros(128))
    assert jnp.max(jnp.abs(jnp.mean(y, -1))) < 1e-5
    assert jnp.max(jnp.abs(jnp.std(y, -1) - 1.0)) < 1e-2


def test_pick_block_rows():
    assert _pick_block_rows(64) == 64
    assert _pick_block_rows(65) == 1
    assert _pick_block_rows(4096) == 64
