"""L2 model invariants: layout, init, mixed precision, train/eval steps,
and the pallas-vs-reference end-to-end parity that anchors the artifacts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import ARTIFACT_SETS, DEFAULT_SETS, MODELS

CFG = MODELS["micro"]


def rand_tokens(seed, b, s, vocab):
    rng = np.random.RandomState(seed)
    return jnp.array(rng.randint(0, vocab, (b, s)), jnp.int32)


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

def test_param_specs_contiguous():
    specs = M.param_specs(CFG)
    off = 0
    for sp in specs:
        assert sp.offset == off
        size = 1
        for d in sp.shape:
            size *= d
        assert sp.size == size
        off += size
    assert off == M.n_params(CFG)


def test_param_specs_decay_policy():
    """Weight decay on weights only — never on biases or LayerNorm affine."""
    for sp in M.param_specs(CFG):
        if sp.name.endswith((".b", ".g")) and "w" not in sp.name.split(".")[-1]:
            assert not sp.decay, sp.name
        if sp.name.endswith(".w") or sp.name in ("wte", "wpe"):
            assert sp.decay, sp.name


def test_layout_scales_with_config():
    for name, cfg in MODELS.items():
        n = M.n_params(cfg)
        # embeddings + 12 per-layer tensors + final LN
        assert len(M.param_specs(cfg)) == 2 + 12 * cfg.n_layer + 2
        assert n > cfg.vocab * cfg.d_model  # at least the embedding


def test_decay_mask_matches_specs():
    mask = M.decay_mask(CFG)
    specs = M.param_specs(CFG)
    assert mask.shape == (M.n_params(CFG),)
    for sp in specs[:6]:
        seg = mask[sp.offset:sp.offset + sp.size]
        assert jnp.all(seg == (1.0 if sp.decay else 0.0))


def test_init_distribution():
    flat = M.init_params(CFG, seed=3)
    specs = {sp.name: sp for sp in M.param_specs(CFG)}
    wte = flat[specs["wte"].offset:specs["wte"].offset + specs["wte"].size]
    assert abs(float(jnp.std(wte)) - 0.02) < 0.002
    ln = specs["h0.ln1.g"]
    assert jnp.all(flat[ln.offset:ln.offset + ln.size] == 1.0)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def test_init_loss_near_uniform():
    flat = M.init_params(CFG, seed=0)
    toks = rand_tokens(0, 4, CFG.max_seqlen + 1, CFG.vocab)
    loss = M.loss_fn(flat, toks, CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_forward_shapes():
    flat = M.init_params(CFG, seed=0)
    for s in (8, 16, CFG.max_seqlen):
        logits = M.forward(flat, rand_tokens(1, 2, s, CFG.vocab), CFG)
        assert logits.shape == (2, s, CFG.vocab)
        assert logits.dtype == jnp.float32


def test_causal_prefix_consistency():
    """logits[:, :s] from a truncated batch equal the full batch's prefix —
    the invariant that makes SLW's truncation sound."""
    flat = M.init_params(CFG, seed=1)
    toks = rand_tokens(2, 2, CFG.max_seqlen, CFG.vocab)
    full = M.forward(flat, toks, CFG)
    s = 16
    short = M.forward(flat, toks[:, :s], CFG)
    assert jnp.max(jnp.abs(full[:, :s] - short)) < 1e-3


def test_pallas_vs_ref_forward():
    """End-to-end L1 anchor: the artifact graph (pallas) and the oracle graph
    produce the same logits."""
    cfg_p = dataclasses.replace(CFG, use_pallas=True)
    cfg_r = dataclasses.replace(CFG, use_pallas=False)
    flat = M.init_params(CFG, seed=2)
    toks = rand_tokens(3, 2, CFG.max_seqlen, CFG.vocab)
    lp = M.forward(flat, toks, cfg_p)
    lr = M.forward(flat, toks, cfg_r)
    assert jnp.max(jnp.abs(lp - lr)) < 1e-3


def test_bf16_forward_runs():
    cfg = dataclasses.replace(CFG, precision="bf16")
    flat = M.init_params(cfg, seed=0)
    logits = M.forward(flat, rand_tokens(0, 2, 16, cfg.vocab), cfg)
    assert logits.dtype == jnp.float32  # f32 logits regardless


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def _state(cfg, seed=0):
    flat = M.init_params(cfg, seed)
    return flat, jnp.zeros_like(flat), jnp.zeros_like(flat), M.decay_mask(cfg)


def _knobs(step, lr=1e-3, clip=1.0):
    """Packed f32[3] per-step runtime scalars (see model.train_step)."""
    return jnp.array([step, lr, clip], jnp.float32)


def test_train_step_learns():
    """A few steps on a repetitive stream must reduce the loss."""
    cfg = CFG
    flat, m, v, dm = _state(cfg)
    rng = np.random.RandomState(0)
    base = rng.randint(0, cfg.vocab, 17)
    stream = np.tile(base, 40)
    f = jax.jit(lambda *a: M.train_step(*a, cfg))
    losses = []
    for i in range(12):
        start = (i * 13) % (len(stream) - 4 * (cfg.max_seqlen + 1))
        batch = stream[start:start + 4 * (cfg.max_seqlen + 1)].reshape(4, -1)
        out = f(flat, m, v, dm, _knobs(i + 1, 3e-3), jnp.array(batch, jnp.int32))
        flat, m, v = out[0], out[1], out[2]
        losses.append(float(out[3][0]))
    assert losses[-1] < losses[0] - 1.0


def test_train_step_outputs():
    cfg = CFG
    flat, m, v, dm = _state(cfg)
    toks = rand_tokens(0, 4, cfg.max_seqlen + 1, cfg.vocab)
    out = M.train_step(flat, m, v, dm, _knobs(1), toks, cfg)
    assert len(out) == 4, "state outputs + one packed stats tensor"
    p_new, m_new, v_new, stats = out
    assert stats.shape == (len(M.STATS_FIELDS),) == (10,)
    loss, grad_l2, var_l1, var_max, mom_l1, clip = stats[:6]
    assert p_new.shape == flat.shape
    assert float(loss) > 0
    assert float(grad_l2) > 0
    assert float(var_max) > 0
    assert float(var_l1) >= float(var_max)
    assert 0 < float(clip) <= 1.0
    # step 1, zero state: m = 0.1*g_clipped, v small
    assert float(mom_l1) > 0
    # the four per-layer-group update-RMS channels: finite and positive
    # (every group sees a nonzero update at step 1)
    for name, value in zip(M.STATS_FIELDS[6:], np.asarray(stats[6:])):
        assert np.isfinite(value) and value > 0, (name, value)


def test_split_grad_apply_matches_fused_step():
    """The data-parallel split (per-shard grad_step → host mean-reduce →
    apply_step on the reduced gradient) reproduces the fused train_step:
    loss_fn is a mean over B·S positions, so with equal shard sizes the mean
    of per-shard gradients is the global-batch gradient."""
    cfg = CFG
    flat, m, v, dm = _state(cfg, seed=3)
    toks = rand_tokens(11, 4, cfg.max_seqlen + 1, cfg.vocab)
    fused = M.train_step(flat, m, v, dm, _knobs(1, 3e-3), toks, cfg)

    # two shards of two contiguous rows each (the Rust sharding rule)
    g0, l0 = M.grad_step(flat, toks[:2], cfg)
    g1, l1 = M.grad_step(flat, toks[2:], cfg)
    g = (g0 + g1) / 2.0
    loss = (l0 + l1) / 2.0
    knobs4 = jnp.array([1.0, 3e-3, 1.0, float(loss)], jnp.float32)
    split = M.apply_step(flat, m, v, dm, knobs4, g, cfg)

    for a, b in zip(fused[:3], split[:3]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(fused[3]), np.asarray(split[3]),
                               rtol=2e-4, atol=1e-6)


def test_apply_step_packs_mean_loss_from_knobs():
    """stats[0] of the apply half is exactly the mean loss delivered in knob
    slot 3 — the replica group's reduced loss, not a recomputation."""
    cfg = CFG
    flat, m, v, dm = _state(cfg, seed=4)
    toks = rand_tokens(12, 4, cfg.max_seqlen + 1, cfg.vocab)
    g, _ = M.grad_step(flat, toks, cfg)
    marker = 7.125  # exactly representable
    knobs4 = jnp.array([1.0, 1e-3, 1.0, marker], jnp.float32)
    out = M.apply_step(flat, m, v, dm, knobs4, g, cfg)
    assert float(out[3][0]) == marker


def test_urms_group_bounds_partition():
    """Groups tile the flat vector exactly, in order, for every preset."""
    for cfg in MODELS.values():
        bounds = M.urms_group_bounds(cfg)
        assert [g for g, _, _ in bounds] == list(M.URMS_GROUPS)
        assert bounds[0][1] == 0
        assert bounds[-1][2] == M.n_params(cfg)
        for (_, _, e), (_, a, _) in zip(bounds, bounds[1:]):
            assert e == a, "spans must be contiguous"
        specs = {sp.name: sp for sp in M.param_specs(cfg)}
        wpe_end = specs["wpe"].offset + specs["wpe"].size
        assert bounds[0][2] == wpe_end, "embed group is wte+wpe"
        assert bounds[3][1] == specs["lnf.g"].offset, "final group is lnf"


def test_urms_matches_flat_update_rms():
    """The packed urms channels equal a direct recomputation of the
    bias-corrected update RMS over each group's span."""
    cfg = CFG
    flat, m, v, dm = _state(cfg, seed=6)
    toks = rand_tokens(7, 4, cfg.max_seqlen + 1, cfg.vocab)
    p_new, m_new, v_new, stats = M.train_step(flat, m, v, dm, _knobs(1), toks, cfg)
    upd = (m_new / (1 - cfg.adam_beta1)) / (
        jnp.sqrt(v_new / (1 - cfg.adam_beta2)) + cfg.adam_eps
    )
    for i, (_, a, b) in enumerate(M.urms_group_bounds(cfg)):
        want = float(jnp.sqrt(jnp.mean(upd[a:b] ** 2)))
        got = float(stats[6 + i])
        assert abs(got - want) / (1.0 + abs(want)) < 1e-5


def test_train_step_pallas_ref_parity():
    """Full fused step parity — the strongest single L1/L2 test."""
    cfg_p = dataclasses.replace(CFG, use_pallas=True)
    cfg_r = dataclasses.replace(CFG, use_pallas=False)
    toks = rand_tokens(5, 4, CFG.max_seqlen + 1, CFG.vocab)
    outs = []
    for cfg in (cfg_p, cfg_r):
        flat, m, v, dm = _state(cfg, seed=4)
        outs.append(M.train_step(flat, m, v, dm, _knobs(1), toks, cfg))
    for a, b, name in zip(outs[0][:3], outs[1][:3], ["p", "m", "v"]):
        diff = float(jnp.max(jnp.abs(a - b)))
        scale = 1.0 + float(jnp.max(jnp.abs(b)))
        assert diff / scale < 2e-3, (name, diff)
    # the packed stats compare per field — a shared scale would let the
    # largest stat mask a regression in a small one (e.g. clip_coef)
    for i, name in enumerate(M.STATS_FIELDS):
        a, b = float(outs[0][3][i]), float(outs[1][3][i])
        assert abs(a - b) / (1.0 + abs(b)) < 2e-3, (name, a, b)


def test_variable_seqlen_buckets():
    """Every bucket of every default artifact set must trace and run."""
    for name in DEFAULT_SETS:
        aset = ARTIFACT_SETS[name]
        if aset.model != "micro":
            continue
        cfg = aset.cfg()
        flat, m, v, dm = _state(cfg)
        for s in aset.seqlen_buckets:
            toks = rand_tokens(0, aset.batch_size, s + 1, cfg.vocab)
            out = M.train_step(flat, m, v, dm, _knobs(1), toks, cfg)
            assert np.all(np.isfinite(np.asarray(out[3])))


# ---------------------------------------------------------------------------
# Eval step
# ---------------------------------------------------------------------------

def test_eval_step_consistent_with_loss():
    cfg = CFG
    flat, *_ = _state(cfg)
    toks = rand_tokens(1, 4, cfg.max_seqlen + 1, cfg.vocab)
    sum_nll, nll, correct = M.eval_step(flat, toks, cfg)
    loss = M.loss_fn(flat, toks, cfg)
    b, s = nll.shape
    assert abs(float(sum_nll) / (b * s) - float(loss)) < 1e-4
    assert correct.shape == nll.shape
    assert jnp.all((correct == 0) | (correct == 1))


def test_eval_step_detects_memorization():
    cfg = CFG
    flat, m, v, dm = _state(cfg)
    rng = np.random.RandomState(1)
    base = rng.randint(0, cfg.vocab, 11)
    batch = jnp.array(np.tile(base, 3 * 4 * (cfg.max_seqlen + 1))[: 4 * (cfg.max_seqlen + 1)]
                      .reshape(4, -1), jnp.int32)
    f = jax.jit(lambda *a: M.train_step(*a, cfg))
    for i in range(25):
        out = f(flat, m, v, dm, _knobs(i + 1, 3e-3), batch)
        flat, m, v = out[0], out[1], out[2]
    _, _, correct = M.eval_step(flat, batch, cfg)
    assert float(jnp.mean(correct)) > 0.8
