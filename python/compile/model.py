"""L2: GPT-2/3-style decoder-only transformer in JAX, calling the L1 Pallas
kernels, plus the pure-functional train/eval steps lowered by aot.py.

State layout — the entire parameter set lives in ONE flat f32 vector (and the
Adam m/v states are flat vectors of the same length). This is deliberate:

* the Rust coordinator (L3) threads state through the AOT train step as three
  opaque Literals — no pytree marshalling on the request path;
* the fused Adam kernel and the paper's gradient-variance statistics
  (l1 norm / max element of sqrt(v_t) *across all dimensions*) operate on
  exactly this flat view, matching the paper's definition;
* checkpointing on the Rust side is a trivial binary dump.

``param_specs`` defines the (name, shape, init, weight-decay) layout; the
manifest emitted by aot.py carries it to Rust so L3 can build the initial
flat vector with its own RNG (same distributions; bit-exactness is not
required — integration tests assert loss ≈ ln(V) at init).

Mixed precision mirrors Megatron's recipe at bf16: activations and matmuls in
bf16 (the gradient-noise channel implicated in the paper's loss spikes),
LayerNorm/softmax statistics and the optimizer in f32.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.adam import adam_update
from .kernels.attention import flash_attention
from .kernels.layernorm import layer_norm


# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    init: str      # "normal" | "zeros" | "ones"
    std: float     # for init == "normal"
    decay: bool    # weight decay applies
    offset: int
    size: int


def param_specs(cfg: ModelConfig) -> list[ParamSpec]:
    d, v, s, l = cfg.d_model, cfg.vocab, cfg.max_seqlen, cfg.n_layer
    proj_std = 0.02 / math.sqrt(2.0 * l)  # GPT-2 residual-projection scaling
    out: list[ParamSpec] = []
    off = 0

    def add(name: str, shape: tuple[int, ...], init: str, std: float, decay: bool):
        nonlocal off
        size = 1
        for dim in shape:
            size *= dim
        out.append(ParamSpec(name, shape, init, std, decay, off, size))
        off += size

    add("wte", (v, d), "normal", 0.02, True)
    add("wpe", (s, d), "normal", 0.01, True)
    for i in range(l):
        p = f"h{i}."
        add(p + "ln1.g", (d,), "ones", 0.0, False)
        add(p + "ln1.b", (d,), "zeros", 0.0, False)
        add(p + "attn.qkv.w", (d, 3 * d), "normal", 0.02, True)
        add(p + "attn.qkv.b", (3 * d,), "zeros", 0.0, False)
        add(p + "attn.proj.w", (d, d), "normal", proj_std, True)
        add(p + "attn.proj.b", (d,), "zeros", 0.0, False)
        add(p + "ln2.g", (d,), "ones", 0.0, False)
        add(p + "ln2.b", (d,), "zeros", 0.0, False)
        add(p + "mlp.fc.w", (d, cfg.d_ff), "normal", 0.02, True)
        add(p + "mlp.fc.b", (cfg.d_ff,), "zeros", 0.0, False)
        add(p + "mlp.proj.w", (cfg.d_ff, d), "normal", proj_std, True)
        add(p + "mlp.proj.b", (d,), "zeros", 0.0, False)
    add("lnf.g", (d,), "ones", 0.0, False)
    add("lnf.b", (d,), "zeros", 0.0, False)
    return out


def n_params(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    return specs[-1].offset + specs[-1].size


def unpack(flat: jax.Array, specs: list[ParamSpec]) -> dict[str, jax.Array]:
    return {
        sp.name: jax.lax.slice(flat, (sp.offset,), (sp.offset + sp.size,)).reshape(sp.shape)
        for sp in specs
    }


def decay_mask(cfg: ModelConfig) -> jax.Array:
    """{0,1} f32 vector over the flat layout — 1 where weight decay applies."""
    specs = param_specs(cfg)
    parts = [jnp.full((sp.size,), 1.0 if sp.decay else 0.0, jnp.float32) for sp in specs]
    return jnp.concatenate(parts)


def init_params(cfg: ModelConfig, seed: int = 0) -> jax.Array:
    """Python-side initializer (tests / artifact parity checks).

    Rust builds the same-distribution vector from the manifest with PCG64.
    """
    specs = param_specs(cfg)
    key = jax.random.PRNGKey(seed)
    parts = []
    for sp in specs:
        if sp.init == "normal":
            key, sub = jax.random.split(key)
            parts.append(jax.random.normal(sub, (sp.size,), jnp.float32) * sp.std)
        elif sp.init == "ones":
            parts.append(jnp.ones((sp.size,), jnp.float32))
        else:
            parts.append(jnp.zeros((sp.size,), jnp.float32))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _ln(x, g, b, cfg: ModelConfig):
    if cfg.use_pallas:
        return layer_norm(x, g, b, eps=cfg.ln_eps)
    return ref.layernorm_ref(x, g, b, eps=cfg.ln_eps)


def _attn(q, k, v, cfg: ModelConfig):
    if cfg.use_pallas:
        return flash_attention(q, k, v, causal=True)
    return ref.attention_ref(q, k, v, causal=True)


def forward(flat: jax.Array, tokens_in: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens_in: i32[B, S] -> logits f32[B, S, V]. S may be any bucket
    length ≤ cfg.max_seqlen (position embeddings are sliced)."""
    b, s = tokens_in.shape
    p = unpack(flat, param_specs(cfg))
    cdtype = jnp.bfloat16 if cfg.precision == "bf16" else jnp.float32

    wte = p["wte"]
    x = wte[tokens_in] + jax.lax.slice(p["wpe"], (0, 0), (s, cfg.d_model))[None, :, :]
    x = x.astype(cdtype)

    for i in range(cfg.n_layer):
        pre = f"h{i}."
        h = _ln(x, p[pre + "ln1.g"], p[pre + "ln1.b"], cfg)
        qkv = h @ p[pre + "attn.qkv.w"].astype(cdtype) + p[pre + "attn.qkv.b"].astype(cdtype)
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, s, cfg.n_head, cfg.d_head).transpose(0, 2, 1, 3)

        a = _attn(heads(q), heads(k), heads(v), cfg)
        a = a.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        a = a @ p[pre + "attn.proj.w"].astype(cdtype) + p[pre + "attn.proj.b"].astype(cdtype)
        x = x + a

        h = _ln(x, p[pre + "ln2.g"], p[pre + "ln2.b"], cfg)
        h = h @ p[pre + "mlp.fc.w"].astype(cdtype) + p[pre + "mlp.fc.b"].astype(cdtype)
        h = jax.nn.gelu(h, approximate=True)
        h = h @ p[pre + "mlp.proj.w"].astype(cdtype) + p[pre + "mlp.proj.b"].astype(cdtype)
        x = x + h

    x = _ln(x, p["lnf.g"], p["lnf.b"], cfg)
    logits = x.astype(jnp.float32) @ wte.T  # tied LM head, f32 logits
    return logits


def loss_fn(flat: jax.Array, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens: i32[B, S+1]; mean next-token NLL over all B·S positions."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits = forward(flat, inp, cfg)
    mean_nll, _, _ = ref.xent_ref(logits, tgt)
    return mean_nll


# ---------------------------------------------------------------------------
# AOT entry points (pure functions of their tensor args)
# ---------------------------------------------------------------------------

# Order of the packed per-step scalar outputs (manifest "stats_fields" —
# mirrored by rust/src/runtime/engine.rs::StepStats). The four urms_* channels
# are the per-layer-group RMS of the bias-corrected Adam update ("A Theory on
# Adam Instability" localizes blow-ups per layer group; Kosson et al. argue
# warmup chiefly bounds early update size) — the sentinel's early-warning
# channels since output layout 3.
STATS_FIELDS = (
    "loss", "grad_l2", "var_l1", "var_max", "mom_l1", "clip_coef",
    "urms_embed", "urms_early", "urms_late", "urms_final",
)

# Layer-group names for the update-RMS channels, in packed order.
URMS_GROUPS = ("embed", "early", "late", "final")


def urms_group_bounds(cfg: ModelConfig) -> list[tuple[str, int, int]]:
    """Contiguous flat-vector spans for the update-RMS layer groups:
    embeddings (wte + wpe), the first half of the transformer stack, the
    second half, and the final LayerNorm. Bounds are python ints so the
    per-group reductions lower to static slices."""
    specs = param_specs(cfg)
    by = {sp.name: sp for sp in specs}
    embed_end = by["wpe"].offset + by["wpe"].size
    lnf = by["lnf.g"].offset
    half = max(cfg.n_layer // 2, 1)
    late_start = by[f"h{half}.ln1.g"].offset if half < cfg.n_layer else lnf
    return [
        ("embed", 0, embed_end),
        ("early", embed_end, late_start),
        ("late", late_start, lnf),
        ("final", lnf, n_params(cfg)),
    ]


def train_step(flat, m, v, dmask, knobs, tokens, cfg: ModelConfig):
    """One fused pre-training step.

    ``knobs`` is a packed f32[3] of the per-step runtime scalars
    ``[step, lr, clip_norm]`` — one tiny host upload per step instead of
    three (clip_norm stays a runtime knob so the gradient-clipping ablation,
    paper Appendix A.3.2 / Fig 10, can sweep it without re-lowering).

    Returns ``(flat', m', v', stats)`` with ``stats`` a packed f32[10] in
    ``STATS_FIELDS`` order — the paper's full instrumentation set plus the
    per-layer-group update-RMS channels (computed from the *new* moments
    with bias correction, i.e. the RMS of the Adam update the step just
    applied, per ``urms_group_bounds`` span). The extra outputs read
    existing intermediates only: the parameter trajectory is unchanged
    from output layout 2. State outputs and the stats tensor are
    *separate results* (not one tuple), so the Rust engine keeps
    params/m/v device-resident across steps and reads back only the
    40-byte stats tensor.
    """
    step, lr, clip_norm = knobs[0], knobs[1], knobs[2]
    loss, grads = jax.value_and_grad(loss_fn)(flat, tokens, cfg)
    if cfg.use_pallas:
        p_new, m_new, v_new, stats = adam_update(
            flat, m, v, grads, step, lr,
            beta1=cfg.adam_beta1, beta2=cfg.adam_beta2, eps=cfg.adam_eps,
            weight_decay=cfg.weight_decay, clip_norm=clip_norm,
            decay_mask=dmask,
        )
    else:
        p_new, m_new, v_new, stats = ref.adam_ref(
            flat, m, v, grads, step, lr,
            beta1=cfg.adam_beta1, beta2=cfg.adam_beta2, eps=cfg.adam_eps,
            weight_decay=cfg.weight_decay, clip_norm=cfg.clip_norm,
            decay_mask=dmask,
        )
    grad_l2, var_l1, var_max, mom_l1, clip_coef = stats
    # per-layer-group RMS of the bias-corrected update just applied
    bc1 = 1.0 - cfg.adam_beta1 ** step
    bc2 = 1.0 - cfg.adam_beta2 ** step
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.adam_eps)
    urms = [
        jnp.sqrt(jnp.mean(jax.lax.slice(upd, (a,), (b,)) ** 2))
        if b > a else jnp.float32(0.0)
        for _, a, b in urms_group_bounds(cfg)
    ]
    packed = jnp.stack([loss, grad_l2, var_l1, var_max, mom_l1, clip_coef, *urms])
    return (p_new, m_new, v_new, packed)


def grad_step(flat, tokens, cfg: ModelConfig):
    """Gradient-only half of the data-parallel split step (output layout 4).

    Each replica runs this against its row-contiguous token shard and ships
    the flat gradient vector to the host, where the replica group
    tree-reduces the per-shard means (`loss_fn` is a mean over B·S
    positions, so with equal shard sizes the mean of per-shard gradients is
    exactly the global-batch gradient). Returns ``(grads f32[n], loss f32)``
    — no optimizer state touched, so the artifact is a pure function of
    (params, tokens).
    """
    loss, grads = jax.value_and_grad(loss_fn)(flat, tokens, cfg)
    return grads, loss


def apply_step(flat, m, v, dmask, knobs, grads, cfg: ModelConfig):
    """Optimizer half of the data-parallel split step (output layout 4).

    ``knobs`` is a packed f32[4] ``[step, lr, clip_norm, mean_loss]`` — the
    reduced mean loss rides in the knob upload so the packed stats vector
    keeps the exact ``STATS_FIELDS`` layout of the fused step and the Rust
    `StepStats` decode is shared. ``grads`` is the tree-reduced global-batch
    gradient; global-norm clipping therefore happens here, on the reduced
    vector, matching the fused step's clip-then-update order. Batch- and
    seqlen-independent, so one artifact per set serves every bucket, and
    every replica applies the identical update to its own device-resident
    state (bit-lockstep fan-back, no O(n_params) parameter broadcast).
    """
    step, lr, clip_norm = knobs[0], knobs[1], knobs[2]
    if cfg.use_pallas:
        p_new, m_new, v_new, stats = adam_update(
            flat, m, v, grads, step, lr,
            beta1=cfg.adam_beta1, beta2=cfg.adam_beta2, eps=cfg.adam_eps,
            weight_decay=cfg.weight_decay, clip_norm=clip_norm,
            decay_mask=dmask,
        )
    else:
        p_new, m_new, v_new, stats = ref.adam_ref(
            flat, m, v, grads, step, lr,
            beta1=cfg.adam_beta1, beta2=cfg.adam_beta2, eps=cfg.adam_eps,
            weight_decay=cfg.weight_decay, clip_norm=cfg.clip_norm,
            decay_mask=dmask,
        )
    grad_l2, var_l1, var_max, mom_l1, clip_coef = stats
    bc1 = 1.0 - cfg.adam_beta1 ** step
    bc2 = 1.0 - cfg.adam_beta2 ** step
    upd = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.adam_eps)
    urms = [
        jnp.sqrt(jnp.mean(jax.lax.slice(upd, (a,), (b,)) ** 2))
        if b > a else jnp.float32(0.0)
        for _, a, b in urms_group_bounds(cfg)
    ]
    packed = jnp.stack([knobs[3], grad_l2, var_l1, var_max, mom_l1, clip_coef, *urms])
    return (p_new, m_new, v_new, packed)


def eval_step(flat, tokens, cfg: ModelConfig):
    """Scoring pass used for validation PPL and the probe-task suite.

    tokens: i32[B, S+1]. Returns (sum_nll f32, per_pos_nll f32[B,S],
    correct f32[B,S]) — Rust applies position masks for probe tasks.
    """
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    logits = forward(flat, inp, cfg)
    _, nll, correct = ref.xent_ref(logits, tgt)
    return jnp.sum(nll), nll, correct
