"""AOT lowering driver: jax train/eval steps → HLO *text* artifacts + manifest.

Run once by `make artifacts`; Python never touches the request path after
this. For every ArtifactSet in configs.DEFAULT_SETS it emits

    artifacts/<set>/train_s<L>.hlo.txt     fused step, one per seqlen bucket L
    artifacts/<set>/grad_s<L>.hlo.txt      grad-only half (data-parallel shards)
    artifacts/<set>/apply.hlo.txt          optimizer half (reduced grads in)
    artifacts/<set>/eval_s<full>.hlo.txt   scoring pass (val PPL / probes)
    artifacts/<set>/manifest.json          shapes, param layout, bucket table

Interchange is HLO TEXT, not a serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version the
`xla` 0.1.6 crate binds) rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import ARTIFACT_SETS, DEFAULT_SETS, ArtifactSet


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text.

    ``return_tuple=False``: the step's outputs stay *separate results* (not
    one wrapped tuple), so the Rust side receives one `PjRtBuffer` per
    output from `execute_b` and can keep the params/m/v state buffers
    device-resident across steps, reading back only the small stats tensor.
    (The legacy output layout 1 wrapped everything in a tuple that had to be
    materialized on the host wholesale every step.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def lower_train(aset: ArtifactSet, seqlen: int) -> str:
    cfg = aset.cfg()
    n = M.n_params(cfg)
    f32 = jnp.float32
    spec = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)  # noqa: E731
    lowered = jax.jit(lambda *a: M.train_step(*a, cfg)).lower(
        spec((n,), f32),                                  # flat params
        spec((n,), f32),                                  # adam m
        spec((n,), f32),                                  # adam v
        spec((n,), f32),                                  # decay mask
        spec((3,), f32),                                  # knobs [step, lr, clip_norm]
        spec((aset.batch_size, seqlen + 1), jnp.int32),   # tokens
    )
    return to_hlo_text(lowered)


def lower_grad(aset: ArtifactSet, seqlen: int) -> str:
    """Gradient-only entry point for the data-parallel replica engine: each
    replica feeds its row-contiguous token shard (shard bsz == the set's
    batch_size) and returns (grads f32[n], loss f32)."""
    cfg = aset.cfg()
    n = M.n_params(cfg)
    lowered = jax.jit(lambda *a: M.grad_step(*a, cfg)).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((aset.batch_size, seqlen + 1), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_apply(aset: ArtifactSet) -> str:
    """Optimizer entry point applying tree-reduced gradients. Batch/seqlen
    independent — one artifact per set. knobs f32[4] = [step, lr, clip_norm,
    mean_loss]."""
    cfg = aset.cfg()
    n = M.n_params(cfg)
    f32 = jnp.float32
    spec = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)  # noqa: E731
    lowered = jax.jit(lambda *a: M.apply_step(*a, cfg)).lower(
        spec((n,), f32),   # flat params
        spec((n,), f32),   # adam m
        spec((n,), f32),   # adam v
        spec((n,), f32),   # decay mask
        spec((4,), f32),   # knobs [step, lr, clip_norm, mean_loss]
        spec((n,), f32),   # reduced grads
    )
    return to_hlo_text(lowered)


def lower_eval(aset: ArtifactSet, seqlen: int) -> str:
    cfg = aset.cfg()
    n = M.n_params(cfg)
    lowered = jax.jit(lambda *a: M.eval_step(*a, cfg)).lower(
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((aset.eval_batch, seqlen + 1), jnp.int32),
    )
    return to_hlo_text(lowered)


def manifest(aset: ArtifactSet) -> dict:
    cfg = aset.cfg()
    specs = M.param_specs(cfg)
    return {
        "set": aset.name,
        "model": {
            "name": cfg.name,
            "n_layer": cfg.n_layer,
            "d_model": cfg.d_model,
            "n_head": cfg.n_head,
            "vocab": cfg.vocab,
            "max_seqlen": cfg.max_seqlen,
            "precision": cfg.precision,
            "ln_eps": cfg.ln_eps,
            "adam_beta1": cfg.adam_beta1,
            "adam_beta2": cfg.adam_beta2,
            "adam_eps": cfg.adam_eps,
            "weight_decay": cfg.weight_decay,
            "clip_norm": cfg.clip_norm,
            "use_pallas": cfg.use_pallas,
        },
        "batch_size": aset.batch_size,
        "eval_batch": aset.eval_batch,
        "n_params": M.n_params(cfg),
        "seqlen_buckets": list(aset.seqlen_buckets),
        "full_only": aset.full_only,
        "train_artifacts": {str(s): f"train_s{s}.hlo.txt" for s in aset.seqlen_buckets},
        "grad_artifacts": {str(s): f"grad_s{s}.hlo.txt" for s in aset.seqlen_buckets},
        "apply_artifact": "apply.hlo.txt",
        "eval_artifact": f"eval_s{cfg.max_seqlen}.hlo.txt",
        # Output layout 4: layout 3's contract (untupled results, state
        # device-resident, f32[10] stats readback) plus the split
        # grad/apply entry points for the data-parallel replica engine —
        # per-bucket grad_s<L> returns (grads, loss) against a shard-sized
        # token batch, and one batch/seqlen-independent apply runs the Adam
        # update from tree-reduced gradients with the mean loss riding in
        # knob slot 3. Engine::load rejects older layouts.
        "output_layout": 4,
        "train_inputs": ["params", "m", "v", "decay_mask", "knobs", "tokens"],
        "knob_fields": ["step", "lr", "clip_norm"],
        "train_outputs": ["params", "m", "v", "stats"],
        "grad_inputs": ["params", "tokens"],
        "grad_outputs": ["grads", "loss"],
        "apply_inputs": ["params", "m", "v", "decay_mask", "knobs", "grads"],
        "apply_knob_fields": ["step", "lr", "clip_norm", "mean_loss"],
        "apply_outputs": ["params", "m", "v", "stats"],
        "stats_fields": list(M.STATS_FIELDS),
        "eval_outputs": ["sum_nll", "per_pos_nll", "correct"],
        "params": [
            {
                "name": sp.name, "shape": list(sp.shape), "init": sp.init,
                "std": sp.std, "decay": sp.decay, "offset": sp.offset, "size": sp.size,
            }
            for sp in specs
        ],
    }


def build_set(aset: ArtifactSet, out_root: Path, force: bool) -> None:
    out = out_root / aset.name
    out.mkdir(parents=True, exist_ok=True)
    man_path = out / "manifest.json"
    todo = []
    for s in aset.seqlen_buckets:
        p = out / f"train_s{s}.hlo.txt"
        if force or not p.exists():
            todo.append(("train", s, p))
        g = out / f"grad_s{s}.hlo.txt"
        if force or not g.exists():
            todo.append(("grad", s, g))
    apply_p = out / "apply.hlo.txt"
    if force or not apply_p.exists():
        todo.append(("apply", 0, apply_p))
    eval_p = out / f"eval_s{aset.cfg().max_seqlen}.hlo.txt"
    if force or not eval_p.exists():
        todo.append(("eval", aset.cfg().max_seqlen, eval_p))

    lower = {
        "train": lambda s: lower_train(aset, s),
        "grad": lambda s: lower_grad(aset, s),
        "apply": lambda _s: lower_apply(aset),
        "eval": lambda s: lower_eval(aset, s),
    }
    for kind, s, path in todo:
        t0 = time.time()
        text = lower[kind](s)
        path.write_text(text)
        print(f"  {aset.name}/{path.name}: {len(text) / 1e6:.2f} MB in {time.time() - t0:.1f}s",
              flush=True)
    man_path.write_text(json.dumps(manifest(aset), indent=1))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sets", default=",".join(DEFAULT_SETS),
                    help="comma-separated artifact set names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_root = Path(args.out_dir)
    names = [n for n in args.sets.split(",") if n]
    unknown = [n for n in names if n not in ARTIFACT_SETS]
    if unknown:
        sys.exit(f"unknown artifact sets: {unknown}; known: {sorted(ARTIFACT_SETS)}")

    t0 = time.time()
    for name in names:
        print(f"[aot] {name}", flush=True)
        build_set(ARTIFACT_SETS[name], out_root, args.force)
    (out_root / "index.json").write_text(json.dumps({"sets": names}, indent=1))
    print(f"[aot] done: {len(names)} sets in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
