"""Model + artifact-set presets, mirrored by rust/src/config/presets.rs.

The paper's testbed (GPT-2 117M/1.5B, GPT-3 125M/1.3B on 128 V100s) is scaled
to a single-core CPU-PJRT box per DESIGN.md §2: each preset keeps the paper's
*ratios* (8x batch scaling, seqlen warmup range, LR multipliers) while the
absolute sizes are chosen so a full experiment suite runs in minutes.

Every artifact set = one model config × one batch size × a ladder of seqlen
buckets (multiples of 8 — the paper's Tensor-Core constraint). aot.py lowers
train_step once per (set, bucket) plus one eval/score step at full length.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    vocab: int
    max_seqlen: int
    precision: str = "f32"  # "f32" | "bf16" (bf16 activations, f32 masters)
    ln_eps: float = 1e-5
    adam_beta1: float = 0.9
    adam_beta2: float = 0.999
    adam_eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    use_pallas: bool = True

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


def _buckets(full: int) -> list[int]:
    """Seqlen bucket ladder: multiples of 8 with denser low end (where the
    pacing function spends its warmup) and the full length at the top."""
    ladder = [8, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512]
    return [b for b in ladder if b < full] + [full]


# ---------------------------------------------------------------------------
# Model presets. Role mapping to the paper:
#   micro  — unit/property tests and pipeline integration (fast)
#   tiny   — plays GPT-2 117M (the grid-search / analysis model)
#   small  — plays GPT-2 1.5B (the unstable large model; bf16 activations)
#   gpt3   — plays GPT-3 125M (token-based LR recipe, batch-size-warmup home)
#   mini   — the end-to-end example model (largest the box trains in minutes)
# ---------------------------------------------------------------------------

MODELS: dict[str, ModelConfig] = {
    "micro": ModelConfig("micro", n_layer=2, d_model=32, n_head=2, vocab=256, max_seqlen=32),
    "tiny": ModelConfig("tiny", n_layer=2, d_model=64, n_head=2, vocab=512, max_seqlen=64,
                        precision="bf16"),
    "small": ModelConfig("small", n_layer=4, d_model=128, n_head=4, vocab=512, max_seqlen=64,
                         precision="bf16"),
    "gpt3": ModelConfig("gpt3", n_layer=2, d_model=64, n_head=2, vocab=512, max_seqlen=64,
                        precision="bf16"),
    "mini": ModelConfig("mini", n_layer=4, d_model=192, n_head=6, vocab=1024, max_seqlen=128),
}


@dataclass(frozen=True)
class ArtifactSet:
    """One lowered family: model × batch size × seqlen buckets.

    ``full_only`` sets are batch-size-warmup rungs: they are only ever run at
    the full sequence length, so just one train_step is lowered for them.
    """
    name: str
    model: str
    batch_size: int
    seqlen_buckets: tuple[int, ...]
    eval_batch: int = 8
    full_only: bool = False

    def cfg(self) -> ModelConfig:
        return MODELS[self.model]


def _set(name: str, model: str, bsz: int, eval_batch: int = 8,
         full_only: bool = False) -> ArtifactSet:
    full = MODELS[model].max_seqlen
    buckets = (full,) if full_only else tuple(_buckets(full))
    return ArtifactSet(name, model, bsz, buckets, eval_batch, full_only)


# Batch scaling mirrors the paper's 512 → 4K (8x). "b8" plays bsz 512,
# "b64" plays bsz 4K; gpt3 ladder {1,2,4,8,16,64} supports batch-size warmup
# (start 16 → 256 in the paper ≙ start 2 → 16/64 here).
ARTIFACT_SETS: dict[str, ArtifactSet] = {s.name: s for s in [
    _set("micro_b4", "micro", 4, eval_batch=4),
    _set("tiny_b8", "tiny", 8),
    _set("tiny_b64", "tiny", 64),
    _set("small_b8", "small", 8),
    _set("small_b16", "small", 16),   # A.3.1 LR sweep (paper used bsz 2K)
    _set("small_b64", "small", 64),
    _set("gpt3_b2", "gpt3", 2, full_only=True),
    _set("gpt3_b4", "gpt3", 4, full_only=True),
    _set("gpt3_b8", "gpt3", 8, full_only=True),
    _set("gpt3_b16", "gpt3", 16, full_only=True),
    _set("gpt3_b64", "gpt3", 64),
    _set("mini_b8", "mini", 8),
]}

# Sets lowered by `make artifacts` by default. gpt3 bsz-warmup rungs and the
# e2e model are included; everything an experiment references must be here.
DEFAULT_SETS = [
    "micro_b4",
    "tiny_b8", "tiny_b64",
    "small_b8", "small_b16", "small_b64",
    "gpt3_b2", "gpt3_b4", "gpt3_b8", "gpt3_b16", "gpt3_b64",
    "mini_b8",
]
