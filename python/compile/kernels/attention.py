"""L1 Pallas kernel: tiled causal flash-attention (fwd + custom-VJP bwd).

This is the compute hot-spot of the GPT model (O(B·L²·H)) — exactly the term
whose quadratic dependence on sequence length L gives Sequence Length Warmup
its time saving (paper §5.1: "reducing the time complexity quadratically for
the self-attention sub-layer").

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's GPU/Megatron
implementation schedules the L² work across threadblocks; here the same
structure is carried by the BlockSpec grid + an in-kernel K/V stream. The
grid walks Q tiles of (block_q, Dh); each grid step streams K/V tiles of
(block_k, Dh) through a fori-loop with an online-softmax accumulator (running
max / denominator), so HBM↔VMEM traffic is O(L²/block) while VMEM residency
stays O(block·Dh). Tile sizes are multiples of 8 — the same alignment the
paper imposes on warmup sequence lengths for Tensor-Core efficiency.

The batch·head axis rides *inside* the block (leading dim) rather than in the
grid: BH is the data-parallel axis a real TPU pod would shard across cores,
so per-core it is a small constant, and keeping it in-block turns the inner
matmuls into a single batched MXU call per tile pair. (It also collapses the
interpret-mode grid from BH·L/bq steps to L/bq, which is what makes the CPU
artifacts fast.) Warmup-length sequences (≤ block_q) run as ONE grid step:
the whole sequence is VMEM-resident — this is where SLW spends its early
steps, at a single fused matmul pair per layer.

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute; the interpret path lowers to plain HLO so the
same kernel runs inside the AOT artifacts on the Rust side.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def default_block(seqlen: int) -> int:
    """Largest multiple-of-8 tile ≤ 128 that divides seqlen (seqlen is a
    multiple of 8 by the SLW contract)."""
    for cand in (128, 64, 32, 16, 8):
        if seqlen % cand == 0:
            return min(cand, seqlen)
    raise ValueError(f"seqlen {seqlen} is not a multiple of 8")


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, block_q, block_k, seqlen, causal):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32) * scale  # [bh, bq, dh]
    bh, bq, dh = q.shape
    q_off = qi * block_q
    row_ids = q_off + jax.lax.iota(jnp.int32, block_q)

    m_i = jnp.full((bh, bq), NEG_INF, jnp.float32)
    l_i = jnp.zeros((bh, bq), jnp.float32)
    acc = jnp.zeros((bh, bq, dh), jnp.float32)

    if causal:
        # Only K/V tiles whose start is ≤ the last query row participate.
        hi = (q_off + block_q + block_k - 1) // block_k
    else:
        hi = seqlen // block_k

    def body(ki, carry):
        m_i, l_i, acc = carry
        k_blk = pl.load(k_ref, (slice(None), pl.dslice(ki * block_k, block_k), slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (slice(None), pl.dslice(ki * block_k, block_k), slice(None))).astype(jnp.float32)
        s = jnp.einsum("bqd,bkd->bqk", q, k_blk)  # [bh, bq, bk]
        if causal:
            col_ids = ki * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = row_ids[:, None] >= col_ids[None, :]
            s = jnp.where(mask[None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bqk,bkd->bqd", p, v_blk)
        return m_new, l_new, acc_new

    m_i, l_i, acc = jax.lax.fori_loop(0, hi, body, (m_i, l_i, acc))
    o_ref[...] = (acc / l_i[..., None]).astype(o_ref.dtype)
    lse_ref[...] = m_i + jnp.log(l_i)


def _fwd(q3, k3, v3, *, scale, block_q, block_k, causal, interpret):
    bh, s, dh = q3.shape
    grid = (s // block_q,)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_q=block_q, block_k=block_k, seqlen=s, causal=causal
    )
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh, block_q, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bh, block_q, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, block_q), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), q3.dtype),
            jax.ShapeDtypeStruct((bh, s), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    return o, lse


# ---------------------------------------------------------------------------
# Backward kernels (flash-attention style recomputation using saved LSE)
# ---------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, scale, block_q, block_k, seqlen, causal):
    qi = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)
    do = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    delta = delta_ref[...]
    bh, bq, dh = q.shape
    q_off = qi * block_q
    row_ids = q_off + jax.lax.iota(jnp.int32, block_q)

    hi = (q_off + block_q + block_k - 1) // block_k if causal else seqlen // block_k
    dq = jnp.zeros((bh, bq, dh), jnp.float32)

    def body(ki, dq):
        k_blk = pl.load(k_ref, (slice(None), pl.dslice(ki * block_k, block_k), slice(None))).astype(jnp.float32)
        v_blk = pl.load(v_ref, (slice(None), pl.dslice(ki * block_k, block_k), slice(None))).astype(jnp.float32)
        s = jnp.einsum("bqd,bkd->bqk", q, k_blk) * scale
        if causal:
            col_ids = ki * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = row_ids[:, None] >= col_ids[None, :]
            s = jnp.where(mask[None, :, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])
        dp = jnp.einsum("bqd,bkd->bqk", do, v_blk)
        ds = p * (dp - delta[..., None])
        return dq + jnp.einsum("bqk,bkd->bqd", ds, k_blk) * scale

    dq = jax.lax.fori_loop(0, hi, body, dq)
    dq_ref[...] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
                    *, scale, block_q, block_k, seqlen, causal):
    ki = pl.program_id(0)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    bh, bk, dh = k.shape
    k_off = ki * block_k
    col_ids = k_off + jax.lax.iota(jnp.int32, block_k)

    lo = k_off // block_q if causal else 0
    dk = jnp.zeros((bh, bk, dh), jnp.float32)
    dv = jnp.zeros((bh, bk, dh), jnp.float32)

    def body(qi, carry):
        dk, dv = carry
        q_blk = pl.load(q_ref, (slice(None), pl.dslice(qi * block_q, block_q), slice(None))).astype(jnp.float32)
        do_blk = pl.load(do_ref, (slice(None), pl.dslice(qi * block_q, block_q), slice(None))).astype(jnp.float32)
        lse_blk = pl.load(lse_ref, (slice(None), pl.dslice(qi * block_q, block_q)))
        delta_blk = pl.load(delta_ref, (slice(None), pl.dslice(qi * block_q, block_q)))
        s = jnp.einsum("bqd,bkd->bqk", q_blk, k) * scale
        if causal:
            row_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)
            mask = row_ids[:, None] >= col_ids[None, :]
            s = jnp.where(mask[None, :, :], s, NEG_INF)
        p = jnp.exp(s - lse_blk[..., None])  # [bh, bq, bk]
        dv_new = dv + jnp.einsum("bqk,bqd->bkd", p, do_blk)
        dp = jnp.einsum("bqd,bkd->bqk", do_blk, v)
        ds = p * (dp - delta_blk[..., None])
        dk_new = dk + jnp.einsum("bqk,bqd->bkd", ds, q_blk) * scale
        return dk_new, dv_new

    dk, dv = jax.lax.fori_loop(lo, seqlen // block_q, body, (dk, dv))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _bwd(q3, k3, v3, o3, lse, do3, *, scale, block_q, block_k, causal, interpret):
    bh, s, dh = q3.shape
    delta = jnp.sum(do3.astype(jnp.float32) * o3.astype(jnp.float32), axis=-1)  # [bh, s]

    dq_kernel = functools.partial(
        _bwd_dq_kernel, scale=scale, block_q=block_q, block_k=block_k, seqlen=s, causal=causal
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(s // block_q,),
        in_specs=[
            pl.BlockSpec((bh, block_q, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((bh, block_q, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, block_q), lambda i: (0, i)),
            pl.BlockSpec((bh, block_q), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bh, block_q, dh), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q3.dtype),
        interpret=interpret,
    )(q3, k3, v3, do3, lse, delta)

    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, scale=scale, block_q=block_q, block_k=block_k, seqlen=s, causal=causal
    )
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(s // block_k,),
        in_specs=[
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((bh, block_k, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, block_k, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, s, dh), lambda i: (0, 0, 0)),
            pl.BlockSpec((bh, s), lambda i: (0, 0)),
            pl.BlockSpec((bh, s), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bh, block_k, dh), lambda i: (0, i, 0)),
            pl.BlockSpec((bh, block_k, dh), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dh), k3.dtype),
            jax.ShapeDtypeStruct((bh, s, dh), v3.dtype),
        ],
        interpret=interpret,
    )(q3, k3, v3, do3, lse, delta)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wrapper (config is static / nondiff)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q3, k3, v3, scale, block_q, block_k, causal):
    o, _ = _fwd(q3, k3, v3, scale=scale, block_q=block_q, block_k=block_k,
                causal=causal, interpret=True)
    return o


def _flash_fwd(q3, k3, v3, scale, block_q, block_k, causal):
    o, lse = _fwd(q3, k3, v3, scale=scale, block_q=block_q, block_k=block_k,
                  causal=causal, interpret=True)
    return o, (q3, k3, v3, o, lse)


def _flash_bwd(scale, block_q, block_k, causal, res, do3):
    q3, k3, v3, o3, lse = res
    dq, dk, dv = _bwd(q3, k3, v3, o3, lse, do3, scale=scale, block_q=block_q,
                      block_k=block_k, causal=causal, interpret=True)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int | None = None, block_k: int | None = None) -> jax.Array:
    """Tiled causal attention. q,k,v: [B,H,S,Dh] -> [B,H,S,Dh].

    Differentiable (custom VJP, flash-style recomputation backward). Matches
    ``ref.attention_ref`` to f32 accumulation accuracy.
    """
    b, h, s, dh = q.shape
    bq = block_q or default_block(s)
    bk = block_k or default_block(s)
    if s % bq or s % bk:
        raise ValueError(f"seqlen {s} must be divisible by blocks ({bq}, {bk})")
    scale = 1.0 / (dh ** 0.5)
    q3 = q.reshape(b * h, s, dh)
    k3 = k.reshape(b * h, s, dh)
    v3 = v.reshape(b * h, s, dh)
    o3 = _flash(q3, k3, v3, scale, bq, bk, causal)
    return o3.reshape(b, h, s, dh)


def attention_vmem_bytes(seqlen: int, dh: int, bh: int = 1, *, block_q: int | None = None,
                         block_k: int | None = None, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency per fwd grid step (EXPERIMENTS.md §Perf):
    Q tile + one K/V stream tile pair + accumulator + softmax stats, per
    batch-head resident on the core."""
    bq = block_q or default_block(seqlen)
    bk = block_k or default_block(seqlen)
    per = (bq * dh) + 2 * (bk * dh) + (bq * dh) + 3 * bq  # q, k+v tiles, acc, m/l/lse
    return per * dtype_bytes * bh
