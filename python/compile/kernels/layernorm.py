"""L1 Pallas kernel: fused LayerNorm (fwd + custom-VJP bwd).

Row-tiled over the flattened (B·S, D) activation matrix: each grid step holds
one (block_rows, D) tile in VMEM, computes mean/rstd in f32 and applies the
affine in a single pass (the GPU version would be one threadblock per row
batch; on TPU the VPU handles the row reductions and the tile shape keeps the
lane dimension = D aligned).

The backward pass needs cross-row reductions for dgamma/dbeta; the kernel
emits per-tile partials which the wrapper sums — the same partial-reduction
shape a multi-core TPU would use.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block_rows(n: int) -> int:
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


def _fwd_kernel(x_ref, g_ref, b_ref, y_ref, mu_ref, rstd_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1)
    xc = x - mu[:, None]
    var = jnp.mean(xc * xc, axis=-1)
    rstd = jax.lax.rsqrt(var + eps)
    y = xc * rstd[:, None] * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    y_ref[...] = y.astype(y_ref.dtype)
    mu_ref[...] = mu
    rstd_ref[...] = rstd


def _bwd_kernel(x_ref, g_ref, mu_ref, rstd_ref, dy_ref, dx_ref, dg_ref, db_ref):
    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    gamma = g_ref[...].astype(jnp.float32)
    mu = mu_ref[...]
    rstd = rstd_ref[...]
    xhat = (x - mu[:, None]) * rstd[:, None]
    dyg = dy * gamma
    m1 = jnp.mean(dyg, axis=-1)
    m2 = jnp.mean(dyg * xhat, axis=-1)
    dx = (dyg - m1[:, None] - xhat * m2[:, None]) * rstd[:, None]
    dx_ref[...] = dx.astype(dx_ref.dtype)
    # per-tile partials, reduced across tiles by the wrapper
    dg_ref[0] = jnp.sum(dy * xhat, axis=0)
    db_ref[0] = jnp.sum(dy, axis=0)


def _fwd(x2, gamma, beta, *, eps, block_rows, interpret):
    n, d = x2.shape
    grid = (n // block_rows,)
    y, mu, rstd = pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x2.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x2, gamma, beta)
    return y, mu, rstd


def _bwd(x2, gamma, mu, rstd, dy2, *, block_rows, interpret):
    n, d = x2.shape
    tiles = n // block_rows
    dx, dg_part, db_part = pl.pallas_call(
        _bwd_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x2.dtype),
            jax.ShapeDtypeStruct((tiles, d), jnp.float32),
            jax.ShapeDtypeStruct((tiles, d), jnp.float32),
        ],
        interpret=interpret,
    )(x2, gamma, mu, rstd, dy2)
    return dx, jnp.sum(dg_part, axis=0), jnp.sum(db_part, axis=0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _ln(x2, gamma, beta, eps, block_rows):
    y, _, _ = _fwd(x2, gamma, beta, eps=eps, block_rows=block_rows, interpret=True)
    return y


def _ln_fwd(x2, gamma, beta, eps, block_rows):
    y, mu, rstd = _fwd(x2, gamma, beta, eps=eps, block_rows=block_rows, interpret=True)
    return y, (x2, gamma, mu, rstd)


def _ln_bwd(eps, block_rows, res, dy2):
    x2, gamma, mu, rstd = res
    dx, dg, db = _bwd(x2, gamma, mu, rstd, dy2, block_rows=block_rows, interpret=True)
    return dx, dg.astype(gamma.dtype), db.astype(gamma.dtype)


_ln.defvjp(_ln_fwd, _ln_bwd)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, *,
               eps: float = 1e-5, block_rows: int | None = None) -> jax.Array:
    """Fused LayerNorm over the last axis. x: [..., D]. Differentiable."""
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    br = block_rows or _pick_block_rows(x2.shape[0])
    y = _ln(x2, gamma, beta, eps, br)
    return y.reshape(x.shape)
