"""L1 Pallas kernel: fused Adam update + gradient clipping + the paper's
variance statistics.

The paper's core instrumentation (Fig 1, 4, 6, 10) is the l1 norm and max
element of Adam's variance state sqrt(v_t), plus the momentum l1 norm
(Appendix A.3.2). Computing these post-hoc would double the optimizer's HBM
traffic, so — like the DeepSpeed implementation the paper shipped — they are
fused into the update kernel itself: each grid step updates one VMEM-sized
chunk of the flat parameter vector and emits partial (l1, max, mom-l1)
reductions, which the wrapper combines.

Gradient clipping needs the *global* l2 norm before any chunk can update, so
the wrapper computes `clip_coef` in a first (cheap, bandwidth-bound) pass and
feeds it to the kernel as a scalar — the same two-phase structure a
data-parallel trainer uses (allreduce of the norm, then local update).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat parameter vectors are padded to a multiple of the chunk. On a real
# TPU core the natural chunk is 64K f32 elements (256 KiB per operand, 7
# operands ≈ 1.8 MiB VMEM, inside the ~16 MiB budget). Under CPU interpret
# mode each grid step pays a fixed emulation cost, so `auto_chunk` collapses
# models that fit to a single grid step — the kernel body is identical, only
# the BlockSpec schedule changes (see EXPERIMENTS.md §Perf L1).
CHUNK = 65536
MAX_CHUNK = 1 << 20


def auto_chunk(n: int) -> int:
    """Single-chunk when the flat vector fits in MAX_CHUNK, else CHUNK tiles."""
    if n <= MAX_CHUNK:
        return ((n + 1023) // 1024) * 1024
    return CHUNK


def _adam_kernel(p_ref, m_ref, v_ref, g_ref, sc_ref,
                 p_out, m_out, v_out, stats_ref,
                 *, beta1, beta2, eps, weight_decay):
    # sc = [step, lr, clip_coef, wd_scale] broadcast to every chunk
    step = sc_ref[0]
    lr = sc_ref[1]
    clip_coef = sc_ref[2]
    wd_scale = sc_ref[3]

    g = g_ref[...].astype(jnp.float32) * clip_coef
    m_new = beta1 * m_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * v_ref[...] + (1.0 - beta2) * (g * g)
    bc1 = 1.0 - jnp.power(beta1, step)
    bc2 = 1.0 - jnp.power(beta2, step)
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
    p = p_ref[...]
    p_out[...] = p - lr * (update + weight_decay * wd_scale * p)
    m_out[...] = m_new
    v_out[...] = v_new

    sqrt_v = jnp.sqrt(v_new)
    stats_ref[0, 0] = jnp.sum(jnp.abs(sqrt_v))
    stats_ref[0, 1] = jnp.max(sqrt_v)
    stats_ref[0, 2] = jnp.sum(jnp.abs(m_new))


def _pad(x: jax.Array, n_pad: int) -> jax.Array:
    return jnp.pad(x, (0, n_pad)) if n_pad else x


def adam_update(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    step: jax.Array,
    lr: jax.Array,
    *,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    clip_norm: float = 1.0,
    decay_mask: jax.Array | None = None,
    chunk: int | None = None,
    interpret: bool = True,
):
    """One fused Adam step over the flat f32 parameter vector.

    Matches ``ref.adam_ref`` exactly (same clipping, bias correction, decay
    masking, and stats). Returns (p', m', v', stats) with
    stats = (grad_l2, var_l1, var_max, mom_l1, clip_coef).

    ``decay_mask`` is folded in by splitting the update into masked/unmasked
    weight-decay contributions: the kernel applies decay scaled by a single
    wd_scale and the wrapper handles the mask via a second correction term —
    to keep the kernel operand count low we instead pre-scale: when a mask is
    given, the wrapper runs the kernel with weight_decay=0 and applies the
    (cheap, elementwise) masked decay outside.
    """
    n = p.shape[0]
    chunk = chunk or auto_chunk(n)
    g = g.astype(jnp.float32)
    grad_l2 = jnp.sqrt(jnp.sum(g * g))
    clip_coef = jnp.minimum(1.0, clip_norm / (grad_l2 + 1e-6))

    n_pad = (-n) % chunk
    tiles = (n + n_pad) // chunk
    p_p, m_p, v_p, g_p = (_pad(x, n_pad) for x in (p, m, v, g))

    kernel_wd = 0.0 if decay_mask is not None else weight_decay
    scalars = jnp.stack([step.astype(jnp.float32), lr.astype(jnp.float32), clip_coef,
                         jnp.float32(1.0)])

    kernel = functools.partial(
        _adam_kernel, beta1=beta1, beta2=beta2, eps=eps, weight_decay=kernel_wd
    )
    p_new, m_new, v_new, stats = pl.pallas_call(
        kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((chunk,), lambda i: (i,)),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((tiles, 3), jnp.float32),
        ],
        interpret=interpret,
    )(p_p, m_p, v_p, g_p, scalars)

    p_new, m_new, v_new = p_new[:n], m_new[:n], v_new[:n]
    if decay_mask is not None:
        p_new = p_new - lr * weight_decay * decay_mask * p

    var_l1 = jnp.sum(stats[:, 0])
    var_max = jnp.max(stats[:, 1])
    mom_l1 = jnp.sum(stats[:, 2])
    return p_new, m_new, v_new, (grad_l2, var_l1, var_max, mom_l1, clip_coef)


def adam_vmem_bytes(chunk: int = CHUNK) -> int:
    """VMEM residency per grid step: 4 input + 3 output f32 chunks."""
    return 7 * chunk * 4
