"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact mathematical twin here.
pytest (python/tests/) asserts allclose between the kernel (interpret=True)
and these references across shape/dtype sweeps — this is the CORE L1
correctness signal for the whole stack: the AOT artifacts embed the Pallas
kernels, so if these match, the Rust-side numerics are anchored.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """Plain softmax attention. q,k,v: [B, H, S, Dh] -> [B, H, S, Dh].

    Softmax statistics are computed in f32 regardless of input dtype
    (matching the kernel), output is cast back to the input dtype.
    """
    b, h, s, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_lse_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True):
    """Attention that also returns the log-sum-exp rows (used by the bwd test)."""
    b, h, s, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    probs = jnp.exp(logits - lse[..., None])
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype), lse


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

def layernorm_ref(x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    """LayerNorm over the last axis, statistics in f32. x: [..., D]."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Adam with gradient clipping + the paper's variance statistics
# ---------------------------------------------------------------------------

def adam_ref(
    p: jax.Array,
    m: jax.Array,
    v: jax.Array,
    g: jax.Array,
    step: jax.Array,
    lr: jax.Array,
    *,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    clip_norm: float = 1.0,
    decay_mask: jax.Array | None = None,
):
    """One fused Adam step over the flat parameter vector.

    Matches the paper's instrumentation: returns the pre-clip global gradient
    l2 norm, and the l1 norm / max element of sqrt(v_t) (Adam's variance
    state), plus the l1 norm of the momentum state (Appendix A.3.2).

    decay_mask: optional {0,1} vector — 1 where weight decay applies
    (weights) and 0 where it does not (biases, LayerNorm, embeddings).

    Returns (p_new, m_new, v_new, stats) where
    stats = (grad_l2, var_l1, var_max, mom_l1, clip_coef).
    """
    g = g.astype(jnp.float32)
    grad_l2 = jnp.sqrt(jnp.sum(g * g))
    clip_coef = jnp.minimum(1.0, clip_norm / (grad_l2 + 1e-6))
    g = g * clip_coef

    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * (g * g)
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    update = m_hat / (jnp.sqrt(v_hat) + eps)
    if decay_mask is not None:
        wd = weight_decay * decay_mask
    else:
        wd = weight_decay
    p_new = p - lr * (update + wd * p)

    sqrt_v = jnp.sqrt(v_new)
    stats = (
        grad_l2,
        jnp.sum(jnp.abs(sqrt_v)),
        jnp.max(sqrt_v),
        jnp.sum(jnp.abs(m_new)),
        clip_coef,
    )
    return p_new, m_new, v_new, stats


# ---------------------------------------------------------------------------
# Cross-entropy (kept jnp-side in the model; oracle used by model tests)
# ---------------------------------------------------------------------------

def xent_ref(logits: jax.Array, targets: jax.Array):
    """Token-level cross entropy. logits [B,S,V] (any float), targets [B,S] i32.

    Returns (mean_nll, per_pos_nll[B,S], correct[B,S]).
    """
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = logz - tgt
    correct = (jnp.argmax(lf, axis=-1) == targets).astype(jnp.float32)
    return jnp.mean(nll), nll, correct
